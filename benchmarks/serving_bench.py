"""Serving benchmark: bucketed-batch pyramid throughput vs single-request.

The claim under test is the serving-runtime design itself: variable
image pyramids served one-at-a-time at their exact geometry pay a fresh
trace + XLA compile (and a fresh MsdaPlan) for EVERY new geometry at
request time, while the bucketed engine pads them into a fixed bucket
ladder whose programs were all AOT-compiled before traffic.

Two phases per mode on the same request mix (reduced vlm config, CPU):

* ``single``   — per-request ``vlm_prefill`` at exact levels + B=1
  decode loop; geometry churn hits jit at request time.
* ``bucketed`` — ``ServeEngine`` (batcher + AOT warm-up); boot cost is
  reported separately from request-time cost, because boot happens
  before traffic in a real deployment.

    PYTHONPATH=src python -m benchmarks.serving_bench [--requests 12]

CSV rows (``name,us_per_call,derived`` — the harness convention): total
request-time wall per mode, per-request latency, and the speedup.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.bench_util import row


def _requests(vc, n: int, max_new: int, seed: int = 0):
    from repro.serving.engine import Request

    (h0, w0), rest = vc.levels[0], vc.levels[1:]
    geometries = [
        vc.levels,
        ((h0 - 1, w0 - 2),) + rest,
        tuple((max(2, h * 3 // 4), max(2, w * 3 // 4)) for h, w in vc.levels),
        tuple((max(1, h // 2), max(1, w // 2)) for h, w in vc.levels),
        ((h0 - 3, w0 - 1),) + tuple((max(1, h // 2), w) for h, w in rest),
    ]
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        lv = geometries[i % len(geometries)]
        S = sum(h * w for h, w in lv)
        reqs.append(Request(
            rid=i, prompt=np.arange(6, dtype=np.int32) + i, max_new=max_new,
            pyramid=rng.standard_normal((S, vc.vision_dim)).astype(np.float32),
            levels=lv))
    return reqs


def bench_serving(n_requests: int = 12, max_new: int = 4, slots: int = 4):
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config, reduced
    from repro.models import vlm
    from repro.serving.engine import ServeEngine

    cfg = reduced(get_config("phi-3-vision-4.2b"))
    vc = cfg.vision
    params = vlm.init_vlm(jax.random.PRNGKey(0), cfg)
    capacity = 64

    # -- single-request baseline: exact geometry, compile-on-demand -------
    reqs = _requests(vc, n_requests, max_new)
    prefill_cache: dict = {}  # levels -> jitted fn (what a naive server keeps)
    decode = jax.jit(lambda p, c, t: vlm.vlm_decode_step(p, cfg, c, t))
    t0 = time.perf_counter()
    for r in reqs:
        lv = tuple(r.levels)
        if lv not in prefill_cache:
            prefill_cache[lv] = jax.jit(
                lambda p, py, tok, lv=lv: vlm.vlm_prefill(
                    p, cfg, py, tok, capacity, levels=lv))
        logits, cache = prefill_cache[lv](
            params, jnp.asarray(r.pyramid[None]), jnp.asarray(r.prompt[None]))
        r.out.append(int(np.asarray(logits)[0].argmax()))
        for _ in range(max_new - 1):
            logits, cache = decode(params, cache,
                                   jnp.asarray([r.out[-1]], np.int32))
            r.out.append(int(np.asarray(logits)[0].argmax()))
    t_single = time.perf_counter() - t0
    single_out = {r.rid: list(r.out) for r in reqs}

    # -- bucketed engine: boot (plans + AOT) separated from traffic -------
    reqs = _requests(vc, n_requests, max_new)
    t0 = time.perf_counter()
    eng = ServeEngine(cfg, params, slots=slots, capacity=capacity)
    eng.warmup(prompt_lengths=(6,))
    t_boot = time.perf_counter() - t0
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    eng.run()
    t_bucket = time.perf_counter() - t0

    toks = n_requests * max_new
    row("serving_single_total", t_single * 1e6,
        f"{len(prefill_cache)} geometries compiled at request time")
    row("serving_single_per_req", t_single / n_requests * 1e6,
        f"{toks / t_single:.1f} tok/s")
    row("serving_bucketed_boot", t_boot * 1e6,
        f"{len(eng.buckets)} buckets, {len(eng.plans)} plans (before traffic)")
    row("serving_bucketed_total", t_bucket * 1e6,
        f"speedup {t_single / t_bucket:.2f}x vs single")
    row("serving_bucketed_per_req", t_bucket / n_requests * 1e6,
        f"{toks / t_bucket:.1f} tok/s")
    s = eng.metrics.snapshot()
    for key, b in sorted(s["buckets"].items()):
        row(f"serving_bucket[{key}]", 0.0,
            f"admitted={b['admitted']} batches={b['batches']} "
            f"pad={100 * b['padding_frac']:.0f}%")
    # sanity: a request admitted ALONE (B=1, empty engine) must reproduce
    # its single-mode tokens exactly — padding and the valid-ratio
    # rescale must not change results.  (Requests from the timed run
    # were admitted in padded batches, where only reduction order — not
    # semantics — may differ from B=1, so they are not compared.)
    solo = _requests(vc, 1, max_new)[0]  # same pyramid/prompt as rid 0
    eng.submit(solo)
    eng.run()
    if solo.out != single_out[0]:
        row("serving_bucketed_MISMATCH", 0.0,
            f"solo {solo.out[:4]} != single {single_out[0][:4]}")
    return t_single, t_bucket


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    bench_serving(args.requests, args.max_new, args.slots)


if __name__ == "__main__":
    main()
