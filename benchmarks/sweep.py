"""Offline autotune sweep: pre-populate winner caches + plan stores for a fleet.

The first step of the ROADMAP "Autotune sweeps" item: run the
``tune="autotune"`` races ONCE, offline, for every (arch, dtype policy)
a fleet will serve, and persist the results twice over —

* the per-device autotune winner cache (``REPRO_MSDA_AUTOTUNE_CACHE`` /
  XDG path) that ``msda_plan`` consults, and
* one :class:`~repro.serving.persistence.PlanStore` file per (arch,
  policy) under ``--store-dir``, which a serving boot points at via
  ``ServeEngine(store_path=...)`` to rebuild its full plan set with
  zero timing runs and zero describe drift.

VLM archs sweep their serving BUCKET geometries (the ladder the
bucketed batcher actually admits), not just the config pyramid.

    PYTHONPATH=src python -m benchmarks.sweep --smoke \
        --store-dir /tmp/fleet-store --policies follow auto

Prints one CSV row per (arch, policy): plan count, tune sources, and
the store path a server should be pointed at.
"""
from __future__ import annotations

import argparse
import os
from collections import Counter


def sweep_one(cfg, policy: str, store_dir: str):
    """Autotune + persist one (config, dtype policy) cell."""
    from repro.serving import batcher as batcher_mod
    from repro.serving.engine import warmup_msda_plans
    from repro.serving.persistence import PlanStore

    buckets = None
    if getattr(cfg, "vision", None) is not None:
        vc = cfg.vision
        buckets = batcher_mod.default_buckets(
            vc.levels, getattr(vc, "bucket_scales", (1.0,)))
    plans = warmup_msda_plans(cfg, dtype_policy=policy, tune="autotune",
                              buckets=buckets)
    path = os.path.join(store_dir, f"{cfg.name}-{policy}.json")
    # meta mirrors ServeEngine's store gate exactly, so a server booted
    # with the same (arch, policy, tune, bucket ladder) restores this
    # store directly via ServeEngine(store_path=...)
    meta = {"arch": cfg.name, "dtype_policy": policy, "tune": "autotune",
            "buckets": [b.key for b in (buckets or ())]}
    n = PlanStore(path).save_plans(plans, meta=meta)
    return plans, path, n


def main() -> None:
    from repro.configs.base import get_config, list_configs, reduced
    from repro.kernels import plan as plan_mod

    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="+", default=None,
                    help="default: every MSDA-bearing config")
    ap.add_argument("--policies", nargs="+", default=["follow", "auto"],
                    choices=("follow", "float32", "bfloat16", "auto"))
    ap.add_argument("--store-dir", default="experiments/plan-store")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs (CI / laptop sweeps)")
    args = ap.parse_args()

    archs = args.archs
    if archs is None:
        archs = [n for n in list_configs()
                 if get_config(n).msda is not None
                 or get_config(n).vision is not None]
    os.makedirs(args.store_dir, exist_ok=True)

    print("arch,policy,plans,stored,sources,store_path")
    for name in archs:
        cfg = get_config(name)
        if args.smoke:
            cfg = reduced(cfg)
        for policy in args.policies:
            plans, path, stored = sweep_one(cfg, policy, args.store_dir)
            sources = "+".join(
                f"{k}:{v}" for k, v in sorted(
                    Counter(p.tuning.source for p in plans).items()))
            print(f"{cfg.name},{policy},{len(plans)},{stored},{sources},{path}",
                  flush=True)
    stats = plan_mod.autotune_stats()
    print(f"# autotune: {stats['raced']} raced, {stats['cache_hits']} cache "
          f"hits; winner cache at {plan_mod.autotune_cache_path()}")


if __name__ == "__main__":
    main()
