"""Offline autotune sweep: pre-populate winner caches + plan stores for a fleet.

The first step of the ROADMAP "Autotune sweeps" item: run the
``tune="autotune"`` races ONCE, offline, for every (arch, dtype policy)
a fleet will serve, and persist the results twice over —

* the per-device autotune winner cache (``REPRO_MSDA_AUTOTUNE_CACHE`` /
  XDG path) that ``msda_plan`` consults, and
* one :class:`~repro.serving.persistence.PlanStore` file per (arch,
  policy) under ``--store-dir``, which a serving boot points at via
  ``ServeEngine(store_path=...)`` to rebuild its full plan set with
  zero timing runs and zero describe drift.

VLM archs sweep their serving BUCKET geometries (the ladder the
bucketed batcher actually admits), not just the config pyramid.

``--mesh-shapes`` adds a mesh-topology axis: for each 'DPxTP' entry the
sweep builds a (data=DP, model=TP) mesh, warms DISTRIBUTED plans (the
sharding ladder — including the 2D dp x tp query-tiling mode — commits
per plan, and ``tune="autotune"`` races 1D vs 2D per topology), and
persists one store per (arch, policy, mesh) that a server restores via
``ServeEngine(store_path=..., mesh=...)``.

    PYTHONPATH=src python -m benchmarks.sweep --smoke \
        --store-dir /tmp/fleet-store --policies follow auto \
        --mesh-shapes 1 2x2

Prints one CSV row per (arch, policy, mesh): plan count, tune sources,
and the store path a server should be pointed at.
"""
from __future__ import annotations

import argparse
import os
from collections import Counter


def parse_mesh_shape(token: str):
    """'1' -> None; 'DPxTP' -> (dp, tp).  Canonical parser lives in
    ``repro.launch.mesh``; bad tokens raise ValueError so the sweep loop
    reports the cell as skipped and keeps going."""
    from repro.launch.mesh import parse_mesh_shape as parse

    return parse(token)


def sweep_one(cfg, policy: str, store_dir: str, mesh_shape=None):
    """Autotune + persist one (config, dtype policy, mesh shape) cell."""
    from repro.kernels import plan as plan_mod
    from repro.launch import mesh as mesh_lib
    from repro.serving import batcher as batcher_mod
    from repro.serving.engine import warmup_msda_plans
    from repro.serving.persistence import PlanStore

    mesh = None
    mtok = "local"
    if mesh_shape is not None:
        mesh = mesh_lib.make_mesh_2d(*mesh_shape)  # raises if too few devices
        mtok = plan_mod.mesh_token(mesh)
    buckets = None
    if getattr(cfg, "vision", None) is not None:
        vc = cfg.vision
        buckets = batcher_mod.default_buckets(
            vc.levels, getattr(vc, "bucket_scales", (1.0,)))
    plans = warmup_msda_plans(cfg, dtype_policy=policy, tune="autotune",
                              buckets=buckets, mesh=mesh)
    name = f"{cfg.name}-{policy}" + ("" if mesh is None else f"-{mtok}")
    path = os.path.join(store_dir, name + ".json")
    # meta mirrors ServeEngine's store gate exactly, so a server booted
    # with the same (arch, policy, tune, bucket ladder, mesh) restores
    # this store directly via ServeEngine(store_path=..., mesh=...)
    meta = {"arch": cfg.name, "dtype_policy": policy, "tune": "autotune",
            "buckets": [b.key for b in (buckets or ())],
            "mesh": None if mesh is None else mtok}
    n = PlanStore(path).save_plans(plans, meta=meta)
    return plans, path, n, mtok


def main() -> None:
    from repro.configs.base import get_config, list_configs, reduced
    from repro.kernels import plan as plan_mod

    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="+", default=None,
                    help="default: every MSDA-bearing config")
    ap.add_argument("--policies", nargs="+", default=["follow", "auto"],
                    choices=("follow", "float32", "bfloat16", "auto"))
    ap.add_argument("--store-dir", default="experiments/plan-store")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs (CI / laptop sweeps)")
    ap.add_argument("--mesh-shapes", nargs="+", default=["1"],
                    help="mesh-topology axis: '1' (no mesh) and/or 'DPxTP' "
                         "entries like 2x2 1x4 — each sweeps the full "
                         "(arch x policy) grid with distributed plans, "
                         "racing 1D vs 2D sharding where both are legal; "
                         "shapes needing more devices than the host has "
                         "are reported and skipped")
    args = ap.parse_args()

    archs = args.archs
    if archs is None:
        archs = [n for n in list_configs()
                 if get_config(n).msda is not None
                 or get_config(n).vision is not None]
    os.makedirs(args.store_dir, exist_ok=True)

    print("arch,policy,mesh,plans,stored,sources,store_path")
    for name in archs:
        cfg = get_config(name)
        if args.smoke:
            cfg = reduced(cfg)
        for policy in args.policies:
            for mtoken in args.mesh_shapes:
                try:
                    shape = parse_mesh_shape(mtoken)
                    plans, path, stored, mtok = sweep_one(
                        cfg, policy, args.store_dir, mesh_shape=shape)
                except ValueError as e:  # bad token / more devices than host
                    reason = str(e).replace(",", ";")  # keep the CSV parseable
                    print(f"{cfg.name},{policy},{mtoken},0,0,skipped:{reason},-",
                          flush=True)
                    continue
                sources = "+".join(
                    f"{k}:{v}" for k, v in sorted(
                        Counter(p.tuning.source for p in plans).items()))
                print(f"{cfg.name},{policy},{mtok},{len(plans)},{stored},"
                      f"{sources},{path}", flush=True)
    stats = plan_mod.autotune_stats()
    print(f"# autotune: {stats['raced']} raced, {stats['cache_hits']} cache "
          f"hits; winner cache at {plan_mod.autotune_cache_path()}")


if __name__ == "__main__":
    main()
