"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see paper_benchmarks for
what each table measures and how it maps to the CPU-only container).

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="skip the slower tables")
    args = ap.parse_args()

    from benchmarks import paper_benchmarks as pb

    print("name,us_per_call,derived")
    t2 = pb.table2_overall()
    pb.table3_speedups(t2)
    pb.backend_dtype_matrix()
    pb.fused_vs_per_level()  # emits BENCH_kernels.json at the repo root
    pb.sparsity_ablation()  # emits BENCH_sparsity.json at the repo root
    pb.fig4_gather_microbench()
    pb.fig5_scatter_microbench()
    if not args.fast:
        pb.table4_ablation()
        pb.bench_detr_train()
    # roofline summary (reads the dry-run sweep if present)
    try:
        from benchmarks import roofline

        print()
        sys.argv = ["roofline", "--mesh", "single"]
        roofline.main()
    except FileNotFoundError:
        print("roofline: experiments/dryrun_results.json missing — run "
              "`python -m repro.launch.dryrun --all --mesh both` first")


if __name__ == "__main__":
    main()
