"""Roofline table: reads the dry-run results and prints §Roofline rows.

    PYTHONPATH=src python -m benchmarks.roofline [--mesh single] [--md]

Per (arch x shape): the three terms (compute / memory / collective, in
seconds), the dominant bottleneck, MODEL_FLOPS = 6·N(_active)·D, the
useful-compute ratio MODEL_FLOPS / (HLO_FLOPs x chips), and the roofline
fraction = max-term utilisation of the ideal (compute-only) time.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict

RESULTS = os.path.join(os.path.dirname(__file__), "../experiments/dryrun_results.json")

ARCH_ORDER = [
    "granite-20b", "stablelm-1.6b", "qwen1.5-32b", "llama3-8b",
    "recurrentgemma-2b", "dbrx-132b", "grok-1-314b", "whisper-large-v3",
    "xlstm-350m", "phi-3-vision-4.2b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load() -> Dict[str, dict]:
    with open(os.path.abspath(RESULTS)) as f:
        return json.load(f)


def fmt_row(cell: dict) -> str:
    r = cell["roofline"]
    tc, tm, tl = r["compute_s"], r["memory_s"], r["collective_s"]
    tmax = max(tc, tm, tl)
    frac = tc / tmax if tmax > 0 else 0.0
    return (
        f"{cell['arch']:>18s} {cell['shape']:>11s} | "
        f"{tc:10.3e} {tm:10.3e} {tl:10.3e} | {r['bottleneck']:>10s} | "
        f"model_flops {cell['model_flops_global']:9.3e} | "
        f"useful {cell['useful_flops_ratio']:5.2f} | roofline_frac {frac:5.2f}"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    results = load()
    print(f"# Roofline ({args.mesh}-pod): compute_s   memory_s   collective_s"
          "  | bottleneck | model_flops | useful | frac")
    worst, coll = None, None
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            cell = results.get(f"{arch}|{shape}|{args.mesh}")
            if cell is None:
                continue
            if cell["status"] == "skip":
                print(f"{arch:>18s} {shape:>11s} | skip: {cell['reason']}")
                continue
            if cell["status"] != "ok":
                print(f"{arch:>18s} {shape:>11s} | ERROR")
                continue
            print(fmt_row(cell))
            r = cell["roofline"]
            tmax = max(r["compute_s"], r["memory_s"], r["collective_s"])
            frac = r["compute_s"] / tmax if tmax else 0
            if worst is None or frac < worst[0]:
                worst = (frac, f"{arch}|{shape}")
            cfrac = r["collective_s"] / tmax if tmax else 0
            if coll is None or cfrac > coll[0]:
                coll = (cfrac, f"{arch}|{shape}")
    if worst:
        print(f"\nworst roofline fraction: {worst[1]} ({worst[0]:.3f})")
        print(f"most collective-bound:   {coll[1]} ({coll[0]:.3f} of step)")


if __name__ == "__main__":
    main()
