#!/usr/bin/env python
"""Docs link checker: documented paths must exist, or CI fails.

Scans README.md + docs/**.md for

* inline markdown links ``[text](target)`` — relative targets must
  resolve to a real file/dir (anchors stripped; http(s) links are not
  fetched, CI must stay hermetic);
* fenced-code / backtick references to repo paths (``src/...``,
  ``tests/...``, ``docs/...``, ``benchmarks/...``, ``examples/...``,
  ``tools/...``) — a doc naming a module that was moved/renamed rots
  silently otherwise.

Run from the repo root (CI: the ``docs`` job, which also executes the
README quickstart via ``examples/quickstart.py``):

    python tools/check_docs.py
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# `path`-style references to tracked top-level trees
PATH_RE = re.compile(
    r"`((?:src|tests|docs|benchmarks|examples|tools)/[A-Za-z0-9_./-]+)`")


def doc_files():
    yield os.path.join(ROOT, "README.md")
    docs = os.path.join(ROOT, "docs")
    for name in sorted(os.listdir(docs)):
        if name.endswith(".md"):
            yield os.path.join(docs, name)


def check_file(path: str):
    errors = []
    text = open(path).read()
    base = os.path.dirname(path)
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        target = target.split("#", 1)[0]
        if not target:
            continue  # pure-anchor link within the page
        if not os.path.exists(os.path.normpath(os.path.join(base, target))):
            errors.append(f"broken link: ({m.group(1)})")
    for m in PATH_RE.finditer(text):
        if not os.path.exists(os.path.join(ROOT, m.group(1))):
            errors.append(f"dangling path reference: `{m.group(1)}`")
    return errors


def main() -> int:
    total = 0
    for path in doc_files():
        rel = os.path.relpath(path, ROOT)
        for err in check_file(path):
            print(f"{rel}: {err}")
            total += 1
    n_files = len(list(doc_files()))
    if total:
        print(f"FAILED: {total} problem(s) across {n_files} docs")
        return 1
    print(f"OK: {n_files} docs, all links and path references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
