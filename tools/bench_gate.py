#!/usr/bin/env python
"""CI perf-regression gate over the ``BENCH_*.json`` trajectory files.

Diffs a freshly emitted trajectory against the committed baseline:

    python tools/bench_gate.py --baseline BENCH_kernels.json \
        --fresh fresh/BENCH_kernels.json

    # several files at once (missing fresh files fail):
    python tools/bench_gate.py --baseline-dir . --fresh-dir fresh \
        --files BENCH_kernels.json BENCH_sparsity.json

Exit codes: 0 = no regressions, 2 = regression(s), 1 = usage/IO error.

Which ``results`` leaves are compared — and in which direction — comes
from the baseline payload's ``gate`` rules (see ``obs.bench.gate_rule``
and ``docs/observability.md``): each rule is an fnmatch pattern over the
flattened dotted key, a direction (``lower``/``higher`` = which way is
better) and a relative tolerance (0.0 = structural, must not move).
Leaves matched by no rule are informational only.  Payloads without a
``gate`` block fall back to a conservative name heuristic: count-like
keys (``launches``, ``gathers``, ``recoveries``, ...) gate structurally,
everything else is informational.

A fresh value *better* than baseline beyond its tolerance is an
improvement; ``--update`` then rewrites the baseline in place — fresh
``results`` become current, the previous results are appended to the
payload's ``history`` list — so the committed trajectory ratchets
forward instead of rotting.
"""
from __future__ import annotations

import argparse
import fnmatch
import json
import os
import sys
from typing import Any, Dict, List, Optional, Tuple

# heuristic fallback for payloads written before gate rules existed:
# keys whose leaf name contains one of these gate structurally (lower is
# better); nothing else gates
_STRUCTURAL_HINTS = ("launches", "gathers", "recoveries", "replan",
                     "retrace", "compiles")


def flatten(results: Any, prefix: str = "") -> Dict[str, float]:
    """Dotted-key map of every numeric leaf (bools excluded)."""
    out: Dict[str, float] = {}
    if isinstance(results, dict):
        for k, v in results.items():
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(results, (int, float)) and not isinstance(results, bool):
        out[prefix[:-1]] = float(results)
    return out


def _heuristic_rules() -> List[Dict[str, Any]]:
    return [{"pattern": f"*{h}*", "direction": "lower", "tolerance": 0.0}
            for h in _STRUCTURAL_HINTS]


def rule_for(key: str, rules: List[Dict[str, Any]]) -> Optional[Dict[str, Any]]:
    leaf = key.rsplit(".", 1)[-1]
    for r in rules:
        if fnmatch.fnmatch(key, r["pattern"]) or fnmatch.fnmatch(leaf, r["pattern"]):
            return r
    return None


def compare(baseline: Dict[str, Any], fresh: Dict[str, Any]
            ) -> Tuple[List[str], List[str], List[str]]:
    """-> (regressions, improvements, notes), each a list of messages."""
    rules = baseline.get("gate") or _heuristic_rules()
    base = flatten(baseline.get("results", {}))
    new = flatten(fresh.get("results", {}))
    regressions, improvements, notes = [], [], []
    for key, b in sorted(base.items()):
        r = rule_for(key, rules)
        if r is None:
            continue
        if key not in new:
            regressions.append(f"{key}: gated metric missing from fresh run "
                               f"(baseline {b:g})")
            continue
        f = new[key]
        tol = float(r.get("tolerance", 0.0))
        lower_better = r.get("direction", "lower") == "lower"
        # relative slack around the baseline; structural rules (tol 0)
        # use a tiny epsilon so float round-trips never false-positive
        eps = abs(b) * 1e-9 + 1e-12
        if lower_better:
            worst, best = b * (1.0 + tol) + eps, b * (1.0 - tol) - eps
            if f > worst:
                regressions.append(
                    f"{key}: {f:g} > {b:g} (+{_pct(f, b)}, tol {tol:g})")
            elif f < best and tol > 0:
                improvements.append(f"{key}: {f:g} < {b:g} (-{_pct(b, f)})")
            elif f < b - eps and tol == 0:
                improvements.append(f"{key}: {f:g} < {b:g} (structural win)")
        else:
            worst, best = b * (1.0 - tol) - eps, b * (1.0 + tol) + eps
            if f < worst:
                regressions.append(
                    f"{key}: {f:g} < {b:g} (-{_pct(b, f)}, tol {tol:g})")
            elif f > best and tol > 0:
                improvements.append(f"{key}: {f:g} > {b:g} (+{_pct(f, b)})")
            elif f > b + eps and tol == 0:
                improvements.append(f"{key}: {f:g} > {b:g} (structural win)")
    for key in sorted(set(new) - set(base)):
        notes.append(f"{key}: new metric ({new[key]:g}), not in baseline")
    return regressions, improvements, notes


def _pct(hi: float, lo: float) -> str:
    if lo == 0:
        return "inf%"
    return f"{100.0 * (hi - lo) / abs(lo):.1f}%"


def update_baseline(baseline_path: str, baseline: Dict[str, Any],
                    fresh: Dict[str, Any]) -> None:
    """Ratchet: fresh results become current, old ones go to history."""
    hist = list(baseline.get("history", []))
    hist.append({"results": baseline.get("results", {}),
                 "created_unix": baseline.get("created_unix")})
    updated = dict(baseline)
    updated["results"] = fresh.get("results", {})
    updated["created_unix"] = fresh.get("created_unix")
    updated["history"] = hist
    tmp = baseline_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(updated, f, indent=1, sort_keys=True)
    os.replace(tmp, baseline_path)


def gate_pair(baseline_path: str, fresh_path: str, *, update: bool = False
              ) -> int:
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
        with open(fresh_path) as f:
            fresh = json.load(f)
    except (OSError, ValueError) as e:
        print(f"[bench-gate] ERROR reading {baseline_path} / {fresh_path}: {e}")
        return 1
    name = os.path.basename(baseline_path)
    if baseline.get("bench") != fresh.get("bench"):
        print(f"[bench-gate] ERROR {name}: bench id mismatch "
              f"({baseline.get('bench')} vs {fresh.get('bench')})")
        return 1
    regressions, improvements, notes = compare(baseline, fresh)
    for m in regressions:
        print(f"[bench-gate] REGRESSION {name}: {m}")
    for m in improvements:
        print(f"[bench-gate] improved {name}: {m}")
    for m in notes:
        print(f"[bench-gate] note {name}: {m}")
    if regressions:
        return 2
    if improvements and update:
        update_baseline(baseline_path, baseline, fresh)
        print(f"[bench-gate] baseline updated: {baseline_path} "
              f"(previous results appended to history)")
    if not improvements:
        print(f"[bench-gate] OK {name}: within tolerance")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", help="committed trajectory file")
    ap.add_argument("--fresh", help="freshly emitted trajectory file")
    ap.add_argument("--baseline-dir", help="directory of committed trajectories")
    ap.add_argument("--fresh-dir", help="directory of fresh trajectories")
    ap.add_argument("--files", nargs="+", default=None,
                    help="file names to gate under --baseline-dir/--fresh-dir")
    ap.add_argument("--update", action="store_true",
                    help="on improvement, ratchet the baseline forward "
                         "(old results appended to its history)")
    args = ap.parse_args(argv)

    pairs: List[Tuple[str, str]] = []
    if args.baseline and args.fresh:
        pairs.append((args.baseline, args.fresh))
    elif args.baseline_dir and args.fresh_dir and args.files:
        for name in args.files:
            pairs.append((os.path.join(args.baseline_dir, name),
                          os.path.join(args.fresh_dir, name)))
    else:
        ap.error("use --baseline + --fresh, or "
                 "--baseline-dir + --fresh-dir + --files")

    rc = 0
    for baseline_path, fresh_path in pairs:
        rc = max(rc, gate_pair(baseline_path, fresh_path, update=args.update))
    return rc


if __name__ == "__main__":
    sys.exit(main())
